"""Transprecise cascade serving trajectory: per-micro-batch model
selection + hierarchical ROI second pass against fixed-model baselines.

  PYTHONPATH=src python benchmarks/cascade_bench.py [--smoke] [--out PATH]

Three scenarios, all pure functions of the (deterministic) trace so
every number replays bit-identically:

* **single-model identity** — a catalog with ONE profile must leave
  every gated serving path byte-for-byte identical to an engine pinned
  to the same ``service_time``: plain detection (drop and track modes),
  static sharding, epoch-loop rebalance, and a seeded replica fault.
  The cascade machinery may cost nothing when there is nothing to
  choose.
* **cascade at overload** — a 2-camera sinusoidal lull/overload cycle
  (peak 10x the heavy model's pooled service rate).  The selector must
  actually move (>= 2 models used, > 0 switches), the cascade's
  tracked mAP must STRICTLY beat every fixed-model baseline, and its
  drop count must stay <= the fast baseline's.  This is the paper's
  transprecision claim in one number: react to pressure by degrading
  precision, not by dropping frames or pinning a cheap model.
* **ROI second pass** — a fast+heavy catalog held at the cheap tier by
  sustained overload: every served batch re-detects its first-pass
  boxes through the heavy model inside cropped windows.  Pixel
  reduction must exceed 50% on the sparse synthetic scenes and the
  recorded trace must pass the audit (ROI containment + switch
  boundaries).

Emits ``BENCH_cascade.json``; exits nonzero unless every acceptance
key holds (CI gates on this).
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np

from faults_bench import canonical

SERVICE = 0.5          # heavy-model virtual service time, both sides


def fast_videos(n_streams, n_frames):
    """Fast-motion synthetic cameras: coasted (interpolated) boxes decay
    across wall bounces, so surviving overload by dropping + coasting
    costs real mAP — the regime the transprecise cascade wins in."""
    from repro.core.stream import SyntheticVideo, VideoSpec
    return {s: SyntheticVideo(VideoSpec("NVR-cascade", 14.0, n_frames,
                                        640, 480, moving_camera=True,
                                        n_objects=8, seed=3 + s,
                                        obj_speed=0.035,
                                        cam_speed=0.006))
            for s in range(n_streams)}


def sinus_trace(n, lo, hi, period, n_streams=2):
    """Arrival trace whose rate swings lo -> hi -> lo sinusoidally: the
    EWMA rate estimator can track it, so selection lag (not estimator
    lag) is what the drop gate measures."""
    from repro.serving import FrameRequest
    img = np.zeros((4, 4, 3), np.float32)
    frames, frame_of, t = [], {}, 0.0
    seqs = [0] * n_streams
    for k in range(n):
        rate = lo + (hi - lo) * 0.5 * (1 - math.cos(2 * math.pi * k
                                                    / period))
        s = k % n_streams
        frames.append(FrameRequest(k, img, t, stream_id=s))
        frame_of[k] = (s, seqs[s])
        seqs[s] += 1
        t += 1.0 / rate
    return frames, frame_of, seqs[0]


# ------------------------------------------------- single-model identity
def scenario_single_model_identity(n_streams, n_frames):
    from repro.serving import (DetectionEngine, FaultSchedule,
                               ModelCatalog, ModelProfile,
                               ShardedDetectionEngine,
                               make_cascade_detect_fn, make_nvr_streams)

    frames, frame_of, videos, dets = make_nvr_streams(n_streams,
                                                      n_frames, rate=2.0)
    cat = ModelCatalog([ModelProfile("only", 0.8, band="yolov3",
                                     service_s=SERVICE)])
    fn = make_cascade_detect_fn(videos, frame_of, cat)
    W, H = videos[0].spec.width, videos[0].spec.height
    roi_kw = dict(catalog=cat, roi=True, roi_bounds=(W, H))
    checks = {}

    def pair(cls, mode_kw, **extra):
        base = cls(detect_fn=fn, n_replicas=2, service_time=SERVICE,
                   **mode_kw, **extra).serve(frames)
        cas = cls(detect_fn=fn, n_replicas=2, **roi_kw,
                  **mode_kw, **extra).serve(frames)
        return canonical(base) == canonical(cas)

    checks["detection_drop"] = pair(DetectionEngine,
                                    {"drop_when_busy": True})
    checks["detection_track"] = pair(DetectionEngine,
                                     {"track_and_interpolate": True})
    checks["sharded_static"] = pair(ShardedDetectionEngine,
                                    {"track_and_interpolate": True},
                                    n_shards=2)
    checks["sharded_rebalance"] = pair(ShardedDetectionEngine,
                                       {"track_and_interpolate": True},
                                       n_shards=2, rebalance=True,
                                       epoch_s=2.0)
    checks["faults"] = pair(DetectionEngine,
                            {"track_and_interpolate": True},
                            faults=FaultSchedule.replica_kill(
                                1.0, replica=0, revive_t=3.0))
    return {"paths": checks}, all(checks.values())


# ------------------------------------------------- cascade at overload
def scenario_cascade_overload(n, period):
    from repro.core import evaluate_streams
    from repro.serving import (DetectionEngine, ModelCatalog,
                               make_cascade_detect_fn, paper_catalog)

    videos = fast_videos(2, n)
    cat = paper_catalog(SERVICE)

    def run(c):
        frames, frame_of, per_stream = sinus_trace(n, 2.0, 20.0, period)
        eng = DetectionEngine(detect_fn=make_cascade_detect_fn(
                                  videos, frame_of, c),
                              catalog=c, n_replicas=2,
                              drop_when_busy=True,
                              track_and_interpolate=True)
        out = eng.serve(frames)
        q = evaluate_streams(videos, out["streams"], per_stream)
        return out, q

    cas, q_cas = run(cat)
    fixed = {}
    for name in cat.names:
        out, q = run(ModelCatalog([cat[name]]))
        fixed[name] = {"map_mean": round(q["map_mean"], 4),
                       "dropped": len(out["dropped"])}
    cas_map = q_cas["map_mean"]
    beats_all = all(cas_map > f["map_mean"] for f in fixed.values())
    drops_ok = len(cas["dropped"]) <= fixed["fast"]["dropped"]
    moved = cas["model_switches"] > 0 and len(cas["models"]) >= 2
    return {
        "trace": {"frames": n, "rate_fps": [2.0, 20.0],
                  "period_frames": period,
                  "heavy_pool_cap_fps": 2 / SERVICE},
        "cascade": {"map_mean": round(cas_map, 4),
                    "dropped": len(cas["dropped"]),
                    "models": cas["models"],
                    "switches": cas["model_switches"],
                    "map_estimate": round(cas["map_estimate"], 4)},
        "fixed": fixed,
    }, beats_all and drops_ok and moved


# --------------------------------------------------- ROI second pass
def scenario_roi_sparse(n):
    from repro.obs import TraceRecorder, audit_recorder
    from repro.serving import (DetectionEngine, ModelCatalog,
                               make_cascade_detect_fn, paper_catalog)

    videos = fast_videos(2, n)
    full = paper_catalog(SERVICE)
    cat = ModelCatalog([full["fast"], full["heavy"]])
    # sustained 12 fps vs heavy pooled cap 4: the selector is pinned at
    # fast, so EVERY served batch takes the hierarchical second pass
    frames, frame_of, _ = sinus_trace(n, 12.0, 12.0, max(n, 2))
    rec = TraceRecorder()
    eng = DetectionEngine(detect_fn=make_cascade_detect_fn(
                              videos, frame_of, cat),
                          catalog=cat, n_replicas=2, drop_when_busy=True,
                          roi=True, roi_bounds=(640, 480), recorder=rec)
    out = eng.serve(frames)
    res = audit_recorder(rec)
    reduction = out["roi_pixel_reduction"]
    ok = (reduction > 0.5 and out["roi_pixels"]["passes"] > 0
          and res.ok)
    return {
        "frames": n,
        "models": out["models"],
        "roi_passes": out["roi_pixels"]["passes"],
        "px_full": out["roi_pixels"]["full"],
        "px_roi": round(out["roi_pixels"]["roi"], 1),
        "pixel_reduction": round(reduction, 4),
        "audit_ok": res.ok,
        "audit_events": len(rec.events),
    }, ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny stream lengths (CI)")
    ap.add_argument("--out", default=str(
        Path(__file__).resolve().parents[1] / "BENCH_cascade.json"))
    args = ap.parse_args()

    import jax

    # the overload cycle needs full periods; smoke keeps two of them
    n_id, (n_ov, period), n_roi = ((3, (192, 96), 24) if args.smoke
                                   else (16, (320, 96), 48))
    t0 = time.perf_counter()
    ident, ok_id = scenario_single_model_identity(3, n_id)
    over, ok_ov = scenario_cascade_overload(n_ov, period)
    roi, ok_roi = scenario_roi_sparse(n_roi)

    out = {
        "bench": "transprecise_cascade_serving",
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "catalog": {"heavy_service_s": SERVICE,
                    "bands": ["yolov3", "ssd300", "yolov3_tiny"]},
        "single_model_identity": ident,
        "cascade_overload": over,
        "roi_sparse": roi,
        "wall_s": round(time.perf_counter() - t0, 2),
        "acceptance": {
            # one-profile catalog == pinned service_time engine,
            # byte-for-byte, on every gated serving path
            "single_model_bit_identical": ok_id,
            # the selector moves, tracked mAP strictly beats every
            # fixed-model baseline, drops stay <= the fast baseline
            "cascade_beats_fixed_models_at_overload": ok_ov,
            # cheap-tier first pass + heavy ROI re-detect reads < 50%
            # of the full-frame pixels, audit-clean
            "roi_pixel_reduction_over_50pct": ok_roi,
        },
    }
    Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(json.dumps(out, indent=2))
    if not all(out["acceptance"].values()):
        failed = [k for k, v in out["acceptance"].items() if not v]
        print(f"ACCEPTANCE FAILED: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
