"""One benchmark per paper table.  Each function returns (rows, derived)
where rows are CSV-ish dicts and derived is the table's headline number.
Paper reference values are embedded for side-by-side comparison."""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import (DEVICE_PROFILES, MODEL_PROFILES, ParallelDetector,
                        n_range)

PAPER_TABLE_IV = {   # ETH-Sunnyday: (model, n) -> (fps, map%)
    ("yolov3", 0): (2.5, 86.9), ("ssd300", 0): (2.3, 74.5),
    ("yolov3", 1): (2.5, 66.1), ("ssd300", 1): (2.3, 69.0),
    ("yolov3", 4): (10.0, 86.5), ("ssd300", 4): (9.2, 77.5),
    ("yolov3", 7): (17.3, 86.9), ("ssd300", 7): (16.0, 74.5),
}
PAPER_TABLE_V = {
    ("yolov3", 0): (2.5, 62.5), ("ssd300", 0): (2.3, 54.4),
    ("yolov3", 1): (2.5, 42.7), ("ssd300", 1): (2.3, 46.7),
    ("yolov3", 4): (10.0, 62.7), ("ssd300", 4): (9.1, 55.4),
    ("yolov3", 7): (17.3, 62.7), ("ssd300", 7): (16.0, 54.7),
}


def _parallel_table(video: str, paper_ref: Dict) -> List[Dict]:
    rows = []
    for model in ("ssd300", "yolov3"):
        off = ParallelDetector(video, model, ["ncs2"], "fcfs").run(
            offline=True)
        rows.append(dict(video=video, model=model, n=0, mode="offline",
                         fps=off.sigma, map=off.map_score * 100,
                         paper_fps=paper_ref.get((model, 0), ("", ""))[0],
                         paper_map=paper_ref.get((model, 0), ("", ""))[1]))
        for n in range(1, 8):
            r = ParallelDetector(video, model, ["ncs2"] * n, "fcfs").run()
            ref = paper_ref.get((model, n), ("", ""))
            rows.append(dict(video=video, model=model, n=n, mode="online",
                             fps=r.sigma, map=r.map_score * 100,
                             drops_per_processed=r.drops_per_processed,
                             paper_fps=ref[0], paper_map=ref[1]))
    return rows


def table_iv():
    """Parallel detection with n NCS2 sticks, ETH-Sunnyday (14 FPS)."""
    rows = _parallel_table("ETH-Sunnyday", PAPER_TABLE_IV)
    n7 = [r for r in rows if r["n"] == 7 and r["model"] == "yolov3"][0]
    return rows, n7["fps"]


def table_v():
    """Parallel detection with n NCS2 sticks, ADL-Rundle-6 (30 FPS)."""
    rows = _parallel_table("ADL-Rundle-6", PAPER_TABLE_V)
    n7 = [r for r in rows if r["n"] == 7 and r["model"] == "yolov3"][0]
    return rows, n7["fps"]


def table_vi():
    """Energy efficiency: detection FPS per watt (YOLOv3, zero-drop)."""
    paper = {"ncs2": (2, 2.5, 1.25), "slow_cpu": (15, 0.4, 0.03),
             "fast_cpu": (125, 13.5, 0.11), "gpu_titanx": (250, 35, 0.14)}
    rows = []
    for name, dev in DEVICE_PROFILES.items():
        mu = dev.mu("yolov3")
        rows.append(dict(device=name, tdp_w=dev.tdp_watts, fps=mu,
                         fps_per_watt=mu / dev.tdp_watts,
                         paper_fps_per_watt=paper[name][2]))
    best = max(rows, key=lambda r: r["fps_per_watt"])
    assert best["device"] == "ncs2", "paper: NCS2 is most energy-efficient"
    return rows, best["fps_per_watt"]


def table_vii():
    """RR vs FCFS schedulers on heterogeneous edge devices (YOLOv3)."""
    paper = {
        ("rr", "fast_cpu", 7): 20.1, ("fcfs", "fast_cpu", 7): 29.0,
        ("rr", "slow_cpu", 7): 3.4, ("fcfs", "slow_cpu", 7): 17.9,
        ("rr", None, 7): 17.3, ("fcfs", None, 7): 17.3,
    }
    rows = []
    for sched in ("rr", "fcfs", "wrr", "proportional"):
        for cpu in (None, "fast_cpu", "slow_cpu"):
            for n in (1, 3, 7):
                devs = ([cpu] if cpu else []) + ["ncs2"] * n
                r = ParallelDetector("ETH-Sunnyday", "yolov3", devs,
                                     sched).run(with_map=False)
                rows.append(dict(scheduler=sched, cpu=cpu or "none",
                                 n_ncs2=n, fps=r.sigma,
                                 paper_fps=paper.get((sched, cpu, n), "")))
    fcfs7 = [r for r in rows if r["scheduler"] == "fcfs"
             and r["cpu"] == "fast_cpu" and r["n_ncs2"] == 7][0]
    return rows, fcfs7["fps"]


def table_ix():
    """Host->accelerator interface bandwidth impact (USB 2.0 vs 3.0)."""
    paper = {("yolov3", "usb2", 7): 8.1, ("yolov3", "usb3", 7): 17.3,
             ("ssd300", "usb2", 7): 13.2, ("ssd300", "usb3", 7): 16.0}
    rows = []
    for model in ("ssd300", "yolov3"):
        for iface in ("usb2", "usb3"):
            for n in (1, 3, 5, 7):
                r = ParallelDetector("ETH-Sunnyday", model, ["ncs2"] * n,
                                     "fcfs", interface=iface).run(
                    with_map=False)
                # shared-hub aggregate goodput cap
                from repro.core.executor import INTERFACE_GOODPUT
                cap = INTERFACE_GOODPUT[iface] / \
                    MODEL_PROFILES[model].frame_bytes
                fps = min(r.sigma, cap)
                rows.append(dict(model=model, interface=iface, n=n,
                                 fps=fps,
                                 paper_fps=paper.get((model, iface, n), "")))
    sat = [r for r in rows if r["model"] == "yolov3"
           and r["interface"] == "usb2" and r["n"] == 7][0]
    return rows, sat["fps"]


def table_x():
    """Host-language serialization (Python GIL vs C++ threads)."""
    paper = {("python", 1): 4.8, ("python", 7): 9.7,
             ("cpp", 1): 4.5, ("cpp", 7): 32.4}
    # Table X uses the async inference API (~2 requests in flight per
    # stick => per-stick rate ~4.7 FPS); the language effect is the host
    # dispatch serialization term.
    rows = []
    fast_ncs2 = DEVICE_PROFILES["ncs2"]
    import dataclasses
    async_dev = dataclasses.replace(fast_ncs2,
                                    fps={"yolov3": 4.7, "ssd300": 4.4})
    from repro.core import DetectorExecutor, FrameStream, SyntheticVideo
    from repro.core import make_scheduler, simulate
    from repro.core.stream import ADL_RUNDLE_6
    for lang, host in (("python", 0.102), ("cpp", 0.002)):
        for n in (1, 2, 3, 5, 7):
            execs = [DetectorExecutor(async_dev, MODEL_PROFILES["yolov3"])
                     for _ in range(n)]
            sched = make_scheduler("fcfs", execs, host_overhead=host)
            res = simulate(FrameStream(SyntheticVideo(ADL_RUNDLE_6)), sched,
                           offline=True)
            rows.append(dict(language=lang, n=n, fps=res.sigma,
                             paper_fps=paper.get((lang, n), "")))
    cpp7 = [r for r in rows if r["language"] == "cpp" and r["n"] == 7][0]
    return rows, cpp7["fps"]


def drop_analysis():
    """§II: λ vs μ mismatch -> drop rate & n-selection (Fig 2/3 analysis)."""
    rows = []
    for lam, mu in ((14.0, 2.5), (30.0, 2.5), (30.0, 2.3)):
        lo, hi = n_range(lam, mu)
        import math
        rows.append(dict(lam=lam, mu=mu,
                         drops_per_processed=math.ceil(lam / mu - 1),
                         n_near_real_time=lo, n_conservative=hi))
    return rows, rows[0]["drops_per_processed"]


def hetero_models():
    """Beyond-paper (§V ongoing work): heterogeneous models x devices."""
    rows = []
    mixes = [
        ("yolo@cpu+4xssd@ncs2", ["yolov3"] + ["ssd300"] * 4,
         ["fast_cpu"] + ["ncs2"] * 4),
        ("4xssd@ncs2", ["ssd300"] * 4, ["ncs2"] * 4),
        ("4xyolo@ncs2", ["yolov3"] * 4, ["ncs2"] * 4),
        ("yolo@cpu+4xyolo@ncs2", ["yolov3"] * 5,
         ["fast_cpu"] + ["ncs2"] * 4),
    ]
    for name, models, devices in mixes:
        for sched in ("rr", "fcfs"):
            r = ParallelDetector("ETH-Sunnyday", models, devices,
                                 sched).run()
            rows.append(dict(mix=name, scheduler=sched, fps=r.sigma,
                             map=r.map_score * 100))
    best = max(rows, key=lambda r: r["fps"])
    return rows, best["fps"]
