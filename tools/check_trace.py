"""Standalone trace audit: replay a saved serving trace file against
the stack's invariants (``repro.obs.audit``):

1. frame conservation — arrived == emitted + dropped + lost,
2. per-stream emit monotonicity,
3. no dispatch to a dead replica,
4. loans LIFO-returned (and all returned by trace end),
5. model switches only at micro-batch boundaries,
6. ROI containment — second-pass windows/detections stay inside the
   parent frame,
7. track-identity continuity — a ``track_import`` must reproduce the
   stream's latest ``track_export`` (same ``next_id`` + confirmed id
   set), and a migrated stream must import its exported table before
   emitting again (a re-seeded tracker fails this).

Accepts either trace serialization:

* the raw recorder dump (``TraceRecorder.to_json``: ``{"events":
  [...], "series": {...}}``), or
* the Chrome-trace-event export (``repro.obs.export``) — the raw
  events are recovered from each traceEvent's ``args``.

  PYTHONPATH=src python tools/check_trace.py out.json [more.json ...]

Exit code 0 = every trace clean, 1 = violations (each printed on its
own line) or no auditable events found.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.obs.audit import audit_events          # noqa: E402
from repro.obs.export import events_from_chrome   # noqa: E402


def load_events(path: str) -> list:
    """Raw recorder events from either trace format (see module doc)."""
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, dict) and "traceEvents" in doc:
        return events_from_chrome(doc)
    if isinstance(doc, dict) and "events" in doc:
        return doc["events"]
    raise ValueError(
        f"{path}: neither a raw trace ('events') nor a Chrome trace "
        "('traceEvents') — is this a serving trace file?")


def check(path: str) -> int:
    """Audit one file; prints the verdict, returns the problem count."""
    events = load_events(path)
    if not events:
        print(f"{path}: no auditable events (was the recorder enabled?)")
        return 1
    res = audit_events(events)
    s = res.stats
    print(f"{path}: {len(events)} events, arrived={s['arrive']} "
          f"emitted={s['emitted']} dropped={s['dropped_final']} "
          f"lost={s['shard_lost']} -> "
          f"{'OK' if res.ok else f'{len(res.violations)} violation(s)'}")
    for v in res.violations:
        print(f"  {v['rule']}: {v.get('why', '')} {v.get('event', '')}")
    return len(res.violations)


def main(argv) -> int:
    if not argv:
        print(__doc__)
        return 1
    problems = 0
    for path in argv:
        try:
            problems += check(path)
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"{path}: {e}")
            problems += 1
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
