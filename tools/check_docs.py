"""Documentation checks, run by CI and `tests/test_docs.py`:

1. **Intra-repo links** — every relative markdown link in `*.md`
   (repo root and subdirectories, hidden/cache dirs skipped) must
   resolve to an existing file or directory. External (`http://`,
   `https://`, `mailto:`) and pure-anchor (`#...`) links are ignored;
   anchor fragments on file links are stripped before the existence
   check.
2. **Doctests** — `doctest.testmod` over the modules whose docstrings
   carry `>>>` examples (`DOCTEST_MODULES`); a failing example fails
   the check, and a listed module with zero collected examples fails
   too (it means the examples were dropped without updating the list).

  PYTHONPATH=src python tools/check_docs.py

Exit code 0 = clean, 1 = problems (each printed on its own line).
"""
from __future__ import annotations

import doctest
import importlib
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

SKIP_DIRS = {"__pycache__", "node_modules", "venv", "build", "dist",
             "site-packages"}
# exemplar material quoted verbatim from OTHER repos — their relative
# links point inside those repos, not this one
SKIP_FILES = {"SNIPPETS.md"}

# modules whose docstrings carry runnable >>> examples
DOCTEST_MODULES = [
    "repro.sharding.serving_rules",
    "repro.serving.engine",
    "repro.obs.trace",
    "repro.obs.metrics",
]

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def markdown_files(root: Path = REPO):
    for path in sorted(root.rglob("*.md")):
        parts = path.relative_to(root).parts
        # skip hidden trees (.git, .venv, .claude, ...) and anything
        # that looks like an install/build dir — local environments
        # must not fail the repo's own doc check
        if any(p.startswith(".") or p in SKIP_DIRS for p in parts[:-1]):
            continue
        if path.name in SKIP_FILES:
            continue
        yield path


def broken_links(root: Path = REPO):
    """All broken relative links as (md_file, link_target) pairs."""
    broken = []
    for md in markdown_files(root):
        for target in _LINK.findall(md.read_text()):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (md.parent / rel).exists():
                broken.append((str(md.relative_to(root)), target))
    return broken


def run_doctests(modules=DOCTEST_MODULES):
    """(failures, attempted) over all listed modules; a module with no
    collected examples counts as one failure."""
    failed = attempted = 0
    for name in modules:
        mod = importlib.import_module(name)
        res = doctest.testmod(mod, verbose=False)
        if res.attempted == 0:
            print(f"doctest: {name} has no examples but is listed in "
                  "DOCTEST_MODULES")
            failed += 1
        failed += res.failed
        attempted += res.attempted
    return failed, attempted


def main() -> int:
    problems = 0
    for md, target in broken_links():
        print(f"broken link: {md} -> {target}")
        problems += 1
    failed, attempted = run_doctests()
    problems += failed
    print(f"checked {sum(1 for _ in markdown_files())} markdown files, "
          f"ran {attempted} doctest examples, "
          f"{problems} problem(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
